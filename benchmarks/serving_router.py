"""Multi-replica router: throughput, availability, and affinity payoff.

Five scenarios over the same tiny LUT_INFER artifact (DESIGN.md §15):

  * single_replica   — 1-replica router, mixed-prompt load: the throughput
                       baseline and token-parity reference
  * two_replicas     — same load over 2 replicas: wall-clock throughput
                       should exceed the single replica (two worker
                       processes decode in parallel) at availability 1.0

Device-step emulation: the three throughput scenarios inject a uniform,
deterministic `FaultSpec(spike_p=1.0, spike_s=DEVICE_STEP_S)` on EVERY
replica — each engine step sleeps a fixed 100 ms, standing in for
device-bound step time. On the host-bound single-core CI container the
replicas' Python/XLA work serializes, but device time (here: sleep)
overlaps across worker processes exactly as accelerator queues would, so
the two_replicas row measures the router's replica scaling rather than
the container's core count. The spike is identical on every replica in
every throughput row (the comparison stays apples-to-apples), it never
perturbs tokens (spikes only sleep), and the prefix rows omit it (their
payoff metric is `prefix_hits`, not wall-clock). Each throughput row also
runs one warmup request per replica before the timed burst so jit
compilation lands outside the measured window.
  * least_loaded_prefix / affinity_prefix
                     — a two-group same-prefix workload (primer request per
                       group, then a burst) over 2 paged replicas under
                       each routing policy: prefix-affinity keeps each
                       group on its rendezvous favorite, so the burst hits
                       the favorite's prefix cache; least_loaded splits the
                       groups across replicas and cold-prefills both sides.
                       `prefix_hits` (summed over replicas) is the payoff
                       metric the committed JSON demonstrates.
  * kill_one_replica — replica 0 crash-loops past max_restarts mid-load;
                       the router fails it over, availability stays 1.0,
                       and every completed request's tokens are
                       byte-identical to the fault-free two-replica run.

Every row records availability (every submitted rid MUST be terminal —
silent loss is an assertion failure), ok-token throughput, and the router's
routing/failover counters. Wall-clock keys (wall_s, tok_s, p50/p99) are
machine noise and skipped by the regression gate; the structural counters
(requests, ok, lost, failovers, affinity_hits, spills) are compared there.
With `json_path` (benchmarks/run.py --json) the rows land in
BENCH_router.json.
"""

from __future__ import annotations

import json
import pathlib
import tempfile
import time

import jax

from repro.configs import build_model, get_arch, reduce_arch
from repro.core.amm import Mode
from repro.serving.artifact import save_artifact
from repro.serving.faults import FaultSpec
from repro.serving.router import EngineRouter

N_REQUESTS = 8
MAX_TOKENS = 8
ENGINE_KW = dict(n_slots=2, max_seq=64, prefill_chunk=8)
PAGED_KW = dict(ENGINE_KW, paged=True, page_size=8)

# emulated device-bound step time for the throughput rows (see module
# docstring): every engine step on every replica sleeps this long
DEVICE_STEP_S = 0.1
_DEVICE_STEP = FaultSpec(spike_p=1.0, spike_s=DEVICE_STEP_S)

# two prefix groups sharing their first two KV pages (16 tokens) within a
# group; 4 requests per group with distinct tails
_GROUP_PREFIX = {"a": [(j * 3) % 256 + 1 for j in range(16)],
                 "b": [(j * 5) % 256 + 2 for j in range(16)]}


def _mixed_prompts() -> list[list[int]]:
    return [[(i * 7 + j) % 256 + 1 for j in range(4 + (i % 5))]
            for i in range(N_REQUESTS)]


def _group_prompts(group: str) -> list[list[int]]:
    return [_GROUP_PREFIX[group] + [100 + 10 * i + len(group)]
            for i in range(4)]


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(round(q * (len(xs) - 1))), len(xs) - 1)]


def _router(artifact, **kw):
    kw.setdefault("engine_kwargs", ENGINE_KW)
    r = EngineRouter(artifact, **kw)
    assert r.wait_ready(timeout=600), "no replica came up"
    return r


def _warm(r: EngineRouter) -> None:
    """Two tiny back-to-back requests (least-loaded placement spreads them
    across both replicas when there are two) compile the engine's prefill
    and decode shapes in every worker before the timed burst. Always two,
    regardless of replica count, so grid ids line up across rows for the
    parity check."""
    grids = [r.submit({"prompt": [251 + i], "max_tokens": 2})
             for i in range(2)]
    for g in grids:
        st = r.wait(g, timeout=600)
        assert st.status == "ok", f"warmup request failed: {st.status}"


def _drive(r: EngineRouter, prompts: list[list[int]]) -> tuple[dict, float]:
    """Submit every prompt as one burst, wait for all; returns per-grid
    results + wall seconds (first submit -> last terminal)."""
    t0 = time.perf_counter()
    submit_t: dict[int, float] = {}
    grids = []
    for p in prompts:
        g = r.submit({"prompt": p, "max_tokens": MAX_TOKENS})
        submit_t[g] = time.perf_counter()
        grids.append(g)
    results = {}
    for g in grids:
        st = r.wait(g, timeout=600)
        results[g] = {"status": st.status, "tokens": list(st.tokens),
                      "retries": st.retries,
                      "latency_s": time.perf_counter() - submit_t[g]}
    return results, time.perf_counter() - t0


def _row(name: str, results: dict, wall_s: float, stats: dict) -> dict:
    statuses = [r["status"] for r in results.values()]
    assert all(s is not None for s in statuses), f"{name}: silently lost rids"
    lat = [r["latency_s"] for r in results.values() if r["status"] == "ok"]
    ok_tokens = sum(len(r["tokens"]) for r in results.values()
                    if r["status"] == "ok")
    per = stats.get("per_replica", {})
    return {
        "scenario": name,
        "requests": len(results),
        "replicas": stats["replicas"],
        "availability": round(statuses.count("ok") / len(results), 3),
        "ok": statuses.count("ok"),
        "error": statuses.count("error"),
        "tok_s": round(ok_tokens / max(wall_s, 1e-9), 1),
        "p50_s": round(_percentile(lat, 0.50), 3),
        "p99_s": round(_percentile(lat, 0.99), 3),
        "wall_s": round(wall_s, 3),
        "routed": stats["routed"],
        "affinity_hits": stats["affinity_hits"],
        "spills": stats["spills"],
        "failovers": stats["failovers"],
        "requeues": stats["requeues"],
        "lost": stats["lost"],
        "replicas_live": stats["replicas_live"],
        "prefix_hits": int(sum(p.get("prefix_hits", 0) for p in per.values())),
    }


def _run_throughput(artifact, name: str, replicas: int,
                    faults=None, supervisor_kwargs=None) -> tuple[dict, dict]:
    r = _router(artifact, replicas=replicas, faults=faults,
                supervisor_kwargs=supervisor_kwargs or {}, retry_budget=2)
    try:
        _warm(r)
        results, wall = _drive(r, _mixed_prompts())
        row = _row(name, results, wall, r.stats())
    finally:
        r.close()
    return row, results


def _run_prefix(artifact, name: str, routing: str) -> dict:
    # spill_threshold high for the affinity row: measure pure stickiness
    # payoff, not the load-spill escape hatch (BENCH rows must be
    # deterministic; the spill path is covered by tests)
    r = _router(artifact, replicas=2, routing=routing,
                engine_kwargs=PAGED_KW, spill_threshold=99)
    try:
        t0 = time.perf_counter()
        results: dict = {}
        # primer per group: its prefill populates the serving replica's
        # prefix cache, making the burst's hit counts deterministic
        for grp in sorted(_GROUP_PREFIX):
            prompts = _group_prompts(grp)
            g = r.submit({"prompt": prompts[0], "max_tokens": MAX_TOKENS})
            st = r.wait(g, timeout=600)
            results[g] = {"status": st.status, "tokens": list(st.tokens),
                          "retries": st.retries, "latency_s": 0.0}
        burst = []
        for grp in sorted(_GROUP_PREFIX):
            burst.extend(_group_prompts(grp)[1:])
        burst_results, _ = _drive(r, burst)
        results.update(burst_results)
        wall = time.perf_counter() - t0
        row = _row(name, results, wall, r.stats())
    finally:
        r.close()
    return row


def main(json_path: str | pathlib.Path | None = None) -> list[dict]:
    arch = reduce_arch(get_arch("qwen3_1p7b"), n_layers=2)
    bundle = build_model(arch, Mode.LUT_INFER)
    params = bundle.init(jax.random.PRNGKey(0))

    rows: list[dict] = []
    cols = ["scenario", "replicas", "requests", "availability", "ok",
            "tok_s", "prefix_hits", "affinity_hits", "spills",
            "failovers", "requeues", "lost"]
    print(",".join(cols))

    def emit(row: dict) -> None:
        rows.append(row)
        print(",".join(str(row.get(c, "")) for c in cols))

    with tempfile.TemporaryDirectory() as td:
        artifact = pathlib.Path(td) / "bench_artifact"
        save_artifact(artifact, bundle, params)

        row, base = _run_throughput(artifact, "single_replica", replicas=1,
                                    faults=[_DEVICE_STEP])
        emit(row)
        row, two = _run_throughput(artifact, "two_replicas", replicas=2,
                                   faults=[_DEVICE_STEP, _DEVICE_STEP])
        _assert_parity(base, two)
        emit(row)

        emit(_run_prefix(artifact, "least_loaded_prefix", "least_loaded"))
        emit(_run_prefix(artifact, "affinity_prefix", "prefix_affinity"))

        # replica 0 crash-loops (the fault respawns with every incarnation)
        # past max_restarts and fails closed mid-load: the router requeues
        # its in-flight requests onto replica 1 — nothing is lost and the
        # replayed generations stay byte-identical (deterministic sampling).
        # kill_at_step=6 fires early in each incarnation's work (warmup is
        # ~3 steps, a request wave ~9): the first kill lands in wave 1 of
        # the burst, and the restarted incarnation — replaying that whole
        # wave — reliably reaches call 6 again and exhausts max_restarts
        # (a late kill index would leave the second incarnation too little
        # replay work to ever hit it, and no failover would occur)
        row, res = _run_throughput(
            artifact, "kill_one_replica", replicas=2,
            faults=[FaultSpec(spike_p=1.0, spike_s=DEVICE_STEP_S,
                              kill_at_step=6), _DEVICE_STEP],
            supervisor_kwargs=dict(faults_once=False, max_restarts=1,
                                   healthy_after_s=3600.0),
        )
        _assert_parity(base, res)
        assert row["availability"] == 1.0, "failover lost requests"
        assert row["failovers"] == 1 and row["lost"] == 0
        emit(row)

    if json_path is not None:
        payload = {
            "schema": "serving_router.v1",
            "arch": "qwen3_1p7b(reduced,L=2)",
            "mode": "lut_infer",
            "backend": jax.default_backend(),
            "engine": ENGINE_KW,
            "device_step_s": DEVICE_STEP_S,
            "rows": rows,
        }
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=1))
        print(f"# wrote {json_path}")
    return rows


def _assert_parity(base: dict, res: dict) -> None:
    """The router adds nothing to the token stream: every ok request is
    byte-identical to the single-replica run of the same workload (grids
    are assigned in submit order on both sides)."""
    for g, r in res.items():
        if r["status"] == "ok":
            assert r["tokens"] == base[g]["tokens"], (
                f"request {g}: tokens diverged through the router"
            )


if __name__ == "__main__":
    import sys

    _JSON = pathlib.Path(__file__).resolve().parents[1] / "BENCH_router.json"
    main(json_path=_JSON if "--json" in sys.argv else None)
